"""Distributed suffix-array construction — the paper's scheme (§IV).

Dataflow per device (inside ``shard_map`` over a flat 1-D mesh):

  Map      : every local suffix -> 16-byte record (prefix key + packed index)
             [repro.core.encoding / kernels.prefix_pack]
  Sample   : TeraSort-style splitter estimation  [distributed.sample_splitters]
  Shuffle  : one all_to_all of records — *indexes move, suffixes stay put*
  Reduce   : lax.sort by (key, index); tie groups refine by fetching the next
             K-token window from the in-memory store (mgetsuffix) inside a
             lax.while_loop until psum(ties)==0
  Output   : per-device sorted index runs == the global suffix array

Static-shape discipline (TPU): the shuffle capacity is sized *exactly* by a
cheap histogram pre-pass (``cfg.adaptive``, two-phase planning — the TPU
analogue of the paper's up-front sampling); store fetches that overflow their
capacity are retried with **group-synchronous advancement**: a tie group only
consumes its next K-token window when every active member's request was
served, so partial service can never produce an inconsistent comparison.

The same entry point drives the read-set mode (the paper's bioinformatics
case, incl. paired-end: concatenate both files' reads) and the long-text
mode (LM-corpus dedup).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import SAConfig
from repro.core import encoding
from repro.core.distributed import (
    axis_size,
    bucket_scatter,
    pvary,
    exchange,
    lex_bucket,
    run_starts,
    sample_splitters,
    shard_map,
)
from repro.core.store import StoreSpec, mget_window, token_bytes
from repro.core.types import (
    KEY_SENTINEL,
    WORD_BITS,
    WORD_MOD,
    Footprint,
    SAResult,
    global_index,
    unpack_index,
)

AXIS = "sa"


def _flat_mesh(mesh: Optional[Mesh]) -> Mesh:
    if mesh is not None:
        return mesh
    devs = np.array(jax.devices())
    return Mesh(devs, (AXIS,))


def _tied(g: jnp.ndarray) -> jnp.ndarray:
    prev = jnp.concatenate([jnp.array([-1], g.dtype), g[:-1]])
    nxt = jnp.concatenate([g[1:], jnp.array([-2], g.dtype)])
    return (g == prev) | (g == nxt)


def _suffix_exhausted(ih, il, depth, *, text_mode, text_len, uniform_len,
                      stride_bits, k):
    """Analytic exhaustion: the first ``depth * k`` tokens already covered the
    whole suffix (locally computable in text mode / uniform-length reads)."""
    if text_mode:
        rem = text_len - il
    else:
        _, off = unpack_index(ih, il, stride_bits)
        rem = uniform_len - off
    return rem <= depth * k


def _refine_tie_groups(g, ih, il, exhausted, *, store_local, spec, cfg,
                       analytic, text_mode, text_len, uniform_len,
                       stride_bits, hard_cap):
    """Group-synchronous window-refinement loop (the reduce-phase core).

    Shared by the full pipeline (:func:`_device_fn`) and the out-of-core
    merge's device-side bucket refinement (:func:`_refiner_fn`): still-tied
    groups of suffixes fetch their next K-token window from the store
    (``mget_window``) and re-sort within the group, a group only consuming a
    window when every active member was served.  Runs under ``shard_map``;
    returns the final ``(g, ih, il, exhausted, depth, stats)`` carry.
    """
    axis = spec.axis
    n = ih.shape[0]
    k = cfg.prefix_len

    zero = pvary(jnp.int32(0), axis)
    depth0 = pvary(jnp.ones((n,), jnp.int32), axis)  # K tokens consumed
    stats0 = dict(
        iters=zero,
        fetch_requests=zero,
        fetch_request_bytes=zero,
        fetch_response_bytes=zero,
        retries=zero,
        max_depth=zero + 1,
    )

    def cond(carry):
        g, ih, il, exhausted, depth, stats = carry
        active = _tied(g) & ~exhausted & (ih != KEY_SENTINEL)
        total = lax.psum(jnp.sum(active), axis)
        return (total > 0) & (stats["iters"] < hard_cap)

    def body(carry):
        g, ih, il, exhausted, depth, stats = carry
        validr = ih != KEY_SENTINEL
        if analytic:
            exhausted = _suffix_exhausted(
                ih, il, depth, text_mode=text_mode, text_len=text_len,
                uniform_len=uniform_len, stride_bits=stride_bits, k=k,
            ) | ~validr
        active = _tied(g) & ~exhausted & validr
        if text_mode:
            row = il + depth * k  # absolute window start owns the request
            off = jnp.zeros_like(il)
        else:
            row, off0 = unpack_index(ih, il, stride_bits)
            off = off0 + depth * k
        resp, exh_new, ok, fs = mget_window(store_local, row, off, active, spec, cfg)
        if cfg.server_pack:
            words = resp  # packed server-side (beyond-paper compression)
        else:
            words = encoding.pack_words(resp, cfg)
        # group-synchronous advance: a group consumes its window only if every
        # active member was served; otherwise the whole group retries.
        member_ok = jnp.where(active, ok, True).astype(jnp.int32)
        seg_ok = jax.ops.segment_min(member_ok, g, num_segments=n)
        adv = (seg_ok[jnp.clip(g, 0, n - 1)] > 0) & validr
        nk_hi = jnp.where(adv & active, words[:, 0], 0)
        nk_lo = jnp.where(adv & active, words[:, 1], 0)
        if not analytic:
            exhausted = jnp.where(adv & active, exh_new, exhausted)
        depth = jnp.where(adv & active, depth + 1, depth)
        exh_i = exhausted.astype(jnp.int32)
        g, nk_hi, nk_lo, ih, il, exh_i, depth = lax.sort(
            (g, nk_hi, nk_lo, ih, il, exh_i, depth), num_keys=5
        )
        exhausted = exh_i > 0
        validr = ih != KEY_SENTINEL
        eq = jnp.concatenate(
            [
                jnp.array([False]),
                (g[1:] == g[:-1])
                & (nk_hi[1:] == nk_hi[:-1])
                & (nk_lo[1:] == nk_lo[:-1]),
            ]
        )
        eq = eq & validr
        g = run_starts(eq)
        stats = dict(
            iters=stats["iters"] + 1,
            fetch_requests=stats["fetch_requests"] + fs.requests,
            fetch_request_bytes=stats["fetch_request_bytes"] + fs.request_bytes,
            fetch_response_bytes=stats["fetch_response_bytes"] + fs.response_bytes,
            retries=stats["retries"] + fs.dropped,
            max_depth=jnp.maximum(stats["max_depth"], jnp.max(depth)),
        )
        return (g, ih, il, exhausted, depth, stats)

    return lax.while_loop(cond, body, (g, ih, il, exhausted, depth0, stats0))


def _map_phase(reads_l, lengths_l, halo_l, *, cfg, rows_per_shard, stride_bits,
               text_mode, text_len):
    """Map + sample + bucket (shared by the histogram pre-pass and main run)."""
    me = lax.axis_index(AXIS)
    if text_mode:
        flat = jnp.concatenate([reads_l.reshape(-1), halo_l.reshape(-1)])
        if cfg.use_pallas:
            from repro.kernels import ops as kops

            keys = kops.prefix_pack(flat, cfg)[:rows_per_shard]
            pos_col = (
                jnp.arange(rows_per_shard, dtype=jnp.int32) + me * rows_per_shard
            )
            rec = jnp.stack(
                [keys[:, 0], keys[:, 1], jnp.zeros_like(pos_col), pos_col], axis=-1
            )
        else:
            rec = encoding.make_records_text(
                flat, cfg, pos_base=me * rows_per_shard, n_emit=rows_per_shard
            )
        pos = jnp.arange(rows_per_shard, dtype=jnp.int32) + me * rows_per_shard
        valid0 = pos < text_len
        rec = jnp.where(valid0[:, None], rec, jnp.full_like(rec, KEY_SENTINEL))
    else:
        rec, valid0 = encoding.make_records_reads(
            reads_l,
            lengths_l,
            cfg,
            read_id_base=me * rows_per_shard,
            stride_bits=stride_bits,
        )
        rec = jnp.where(valid0[:, None], rec, jnp.full_like(rec, KEY_SENTINEL))
    s_hi, s_lo = sample_splitters(rec[:, 0], rec[:, 1], cfg.samples_per_shard, AXIS)
    bucket = lex_bucket(rec[:, 0], rec[:, 1], s_hi, s_lo)
    # invalid padding records go to a local dump bucket, never shipped
    nb = axis_size(AXIS)
    bucket = jnp.where(valid0.reshape(-1), bucket, jnp.int32(nb))
    return rec, valid0, bucket


def _hist_fn(reads_l, lengths_l, halo_l, *, cfg, num_shards, rows_per_shard,
             stride_bits, text_mode, text_len, **_):
    """Pre-pass: per-(sender,bucket) record counts -> exact shuffle capacity."""
    _, _, bucket = _map_phase(
        reads_l, lengths_l, halo_l, cfg=cfg, rows_per_shard=rows_per_shard,
        stride_bits=stride_bits, text_mode=text_mode, text_len=text_len,
    )
    hist = jnp.bincount(bucket, length=num_shards + 1)[:num_shards]
    return hist[None, :].astype(jnp.int32)


def _device_fn(
    reads_l: jnp.ndarray,
    lengths_l: jnp.ndarray,
    halo_l: jnp.ndarray,
    *,
    cfg: SAConfig,
    num_shards: int,
    rows_per_shard: int,
    row_len: int,
    stride_bits: int,
    shuffle_cap: int,
    fetch_cap: int,
    max_rounds: int,
    uniform_len: Optional[int],
    text_mode: bool,
    text_len: int,
):
    """Per-device SA pipeline body (runs under shard_map)."""
    d = num_shards
    k = cfg.prefix_len

    rec, valid0, bucket = _map_phase(
        reads_l, lengths_l, halo_l, cfg=cfg, rows_per_shard=rows_per_shard,
        stride_bits=stride_bits, text_mode=text_mode, text_len=text_len,
    )
    n_valid_local = jnp.sum(valid0).astype(jnp.int32)

    # ---- Shuffle: the 16-byte-record all_to_all ----------------------
    buf, slot, _ = bucket_scatter(rec, bucket, d + 1, shuffle_cap, KEY_SENTINEL)
    drop_shuffle = jnp.sum(
        valid0.reshape(-1) & (slot >= d * shuffle_cap)
    ).astype(jnp.int32)
    recv = exchange(buf[:d], AXIS).reshape(d * shuffle_cap, 4)

    # ---- Reduce: initial sort ----------------------------------------
    kh, kl, ih, il = (recv[:, i] for i in range(4))
    kh, kl, ih, il = lax.sort((kh, kl, ih, il), num_keys=4)
    validr = ih != KEY_SENTINEL

    eq = jnp.concatenate(
        [jnp.array([False]), (kh[1:] == kh[:-1]) & (kl[1:] == kl[:-1])]
    )
    eq = eq & validr
    g = run_starts(eq)

    # exhausted = the first depth*K tokens already covered the whole suffix.
    # Analytic when remaining length is locally computable (text mode /
    # uniform reads — the paper's skip-the-short-suffixes trick, §IV-B);
    # variable-length reads resolve lazily via fetch-response flags.
    analytic = text_mode or (uniform_len is not None)

    if analytic:
        exhausted = _suffix_exhausted(
            ih, il, jnp.int32(1), text_mode=text_mode, text_len=text_len,
            uniform_len=uniform_len, stride_bits=stride_bits, k=k,
        )
    else:
        exhausted = jnp.zeros_like(validr)  # resolved lazily via fetch flags
    exhausted = exhausted | ~validr

    spec = StoreSpec(
        axis=AXIS,
        num_shards=d,
        rows_per_shard=rows_per_shard,
        row_len=row_len,
        request_capacity=fetch_cap,
    )
    # text mode: local store shard = tokens + right halo so windows starting
    # near the shard boundary stay a single-owner lookup.
    if text_mode:
        store_local = jnp.concatenate([reads_l.reshape(-1), halo_l.reshape(-1)])
        store_local = store_local[:, None]
    else:
        store_local = reads_l

    g, ih, il, exhausted, depth, stats = _refine_tie_groups(
        g, ih, il, exhausted, store_local=store_local, spec=spec, cfg=cfg,
        analytic=analytic, text_mode=text_mode, text_len=text_len,
        uniform_len=uniform_len, stride_bits=stride_bits,
        hard_cap=2 * max_rounds + 8,
    )

    # unresolved = groups still tied and not exhausted when hard_cap hit
    unresolved = jnp.sum(
        _tied(g) & ~exhausted & (ih != KEY_SENTINEL)
    ).astype(jnp.int32)
    count = jnp.sum(ih != KEY_SENTINEL).astype(jnp.int32)
    statvec = jnp.stack(
        [
            count,
            n_valid_local,
            stats["iters"],
            stats["fetch_requests"],
            stats["fetch_request_bytes"],
            stats["fetch_response_bytes"],
            drop_shuffle,
            stats["retries"],
            unresolved,
            stats["max_depth"],
        ]
    )
    return ih, il, statvec[None, :]


def plan(corpus_shape, cfg: SAConfig, num_shards: int, lengths=None):
    """Static planning shared by run and dry-run paths."""
    text_mode = len(corpus_shape) == 1
    if text_mode:
        n = corpus_shape[0]
        rows_per_shard = -(-n // num_shards)
        row_len, l = 1, 1
        stride_bits = 0
        n_local = rows_per_shard
        text_len = n
        uniform_len = None
    else:
        r, l = corpus_shape
        rows_per_shard = -(-r // num_shards)
        row_len = l
        stride_bits = int(math.ceil(math.log2(l + 1)))
        n_local = rows_per_shard * (l + 1)
        text_len = 0
        uniform_len = l if lengths is None else None
    shuffle_cap = max(1, int(math.ceil(n_local * cfg.shuffle_slack / num_shards)))
    if cfg.max_rounds:
        max_rounds = cfg.max_rounds
    elif text_mode:
        max_rounds = int(math.ceil(corpus_shape[0] / cfg.prefix_len)) + 1
    else:
        max_rounds = int(math.ceil((l + 1) / cfg.prefix_len)) + 1
    return dict(
        text_mode=text_mode,
        rows_per_shard=rows_per_shard,
        row_len=row_len,
        stride_bits=stride_bits,
        shuffle_cap=shuffle_cap,
        max_rounds=max_rounds,
        uniform_len=uniform_len,
        text_len=text_len,
        n_local=n_local,
    )


def _shard_inputs(corpus, lengths, cfg: SAConfig, d: int, info):
    corpus = np.asarray(corpus, np.int32)
    rows = info["rows_per_shard"]
    k = cfg.prefix_len
    if info["text_mode"]:
        pad = rows * d - corpus.shape[0]
        flat = np.pad(corpus, (0, pad))
        data = flat.reshape(d * rows, 1)
        lens = np.zeros((d * rows,), np.int32)
        halo = np.zeros((d, k), np.int32)
        for i in range(d - 1):
            seg = flat[(i + 1) * rows : min((i + 1) * rows + k, d * rows)]
            halo[i, : seg.shape[0]] = seg
        halo = halo.reshape(d * k)
    else:
        r, l = corpus.shape
        pad = rows * d - r
        data = np.pad(corpus, ((0, pad), (0, 0)))
        if lengths is None:
            lens = np.concatenate(
                [np.full((r,), l, np.int32), np.full((pad,), -1, np.int32)]
            )
        else:
            lens = np.concatenate(
                [np.asarray(lengths, np.int32), np.full((pad,), -1, np.int32)]
            )
        halo = np.zeros((d,), np.int32)
    return data, lens, halo


def make_pipeline(corpus_shape, cfg: SAConfig, mesh: Mesh, lengths=None,
                  shuffle_cap: Optional[int] = None):
    """Build the jitted shard_map'd pipeline for given static shapes.

    Returns (jitted_fn, info).  Usable both for execution and for
    ``.lower()`` in the multi-pod dry-run.
    """
    d = mesh.devices.size
    info = plan(corpus_shape, cfg, d, lengths)
    if shuffle_cap is not None:
        info = dict(info, shuffle_cap=shuffle_cap)
    fetch_cap = max(
        1,
        int(math.ceil(d * info["shuffle_cap"] * cfg.fetch_fraction
                      * cfg.shuffle_slack / d)),
    )
    fn = partial(
        _device_fn,
        cfg=cfg,
        num_shards=d,
        rows_per_shard=info["rows_per_shard"],
        row_len=info["row_len"],
        stride_bits=info["stride_bits"],
        shuffle_cap=info["shuffle_cap"],
        fetch_cap=fetch_cap,
        max_rounds=info["max_rounds"],
        uniform_len=info["uniform_len"],
        text_mode=info["text_mode"],
        text_len=info["text_len"],
    )
    smapped = shard_map(
        fn, mesh=mesh, in_specs=(P(AXIS), P(AXIS), P(AXIS)),
        out_specs=(P(AXIS), P(AXIS), P(AXIS)),
        # interpret-mode Pallas mixes varying/unvarying internals; relax the
        # vma checker when kernels are routed through pallas_call.
        check_vma=not cfg.use_pallas,
    )
    return jax.jit(smapped), info


def _exact_shuffle_cap(corpus_shape, cfg, mesh, data, lens, halo, info) -> int:
    """Histogram pre-pass: exact max per-(sender,bucket) count."""
    d = mesh.devices.size
    fn = partial(
        _hist_fn,
        cfg=cfg,
        num_shards=d,
        rows_per_shard=info["rows_per_shard"],
        stride_bits=info["stride_bits"],
        text_mode=info["text_mode"],
        text_len=info["text_len"],
    )
    smapped = shard_map(
        fn, mesh=mesh, in_specs=(P(AXIS), P(AXIS), P(AXIS)), out_specs=P(AXIS),
        check_vma=not cfg.use_pallas,
    )
    hist = np.asarray(jax.jit(smapped)(data, lens, halo))
    return max(1, int(hist.max()))


def build_suffix_array(
    corpus,
    lengths=None,
    cfg: SAConfig = SAConfig(),
    mesh: Optional[Mesh] = None,
) -> SAResult:
    """Build the suffix array of ``corpus`` with the paper's scheme.

    corpus: (R, L) int32 reads (tokens 1..V, 0 padding) or (n,) int32 text.
    """
    mesh = _flat_mesh(mesh)
    d = mesh.devices.size
    info = plan(np.shape(corpus), cfg, d, lengths)
    data, lens, halo = _shard_inputs(corpus, lengths, cfg, d, info)
    sharding = NamedSharding(mesh, P(AXIS))
    data = jax.device_put(data, sharding)
    lens = jax.device_put(lens, sharding)
    halo = jax.device_put(halo, sharding)

    shuffle_cap = None
    if cfg.adaptive:
        shuffle_cap = _exact_shuffle_cap(
            np.shape(corpus), cfg, mesh, data, lens, halo, info
        )
    jitted, info = make_pipeline(
        np.shape(corpus), cfg, mesh, lengths, shuffle_cap=shuffle_cap
    )
    ih, il, statmat = jitted(data, lens, halo)
    return _finalize(
        np.asarray(ih), np.asarray(il), np.asarray(statmat), corpus, cfg
    )


def _finalize(ih, il, statmat, corpus, cfg: SAConfig) -> SAResult:
    d = statmat.shape[0]
    per_dev = ih.shape[0] // d
    chunks = []
    for i in range(d):
        lo = i * per_dev
        cnt = int(statmat[i, 0])
        chunks.append(global_index(ih[lo : lo + cnt], il[lo : lo + cnt]))
    sa = np.concatenate(chunks) if chunks else np.zeros((0,), np.int64)

    corpus = np.asarray(corpus)
    tb = token_bytes(cfg.vocab_size)
    n_suffix = int(statmat[:, 1].sum())
    fp = Footprint(
        input=int(corpus.size) * tb,
        store_put=int(corpus.size) * tb,
        shuffle=n_suffix * 16,
        fetch_request=int(statmat[:, 4].sum()),
        fetch_response=int(statmat[:, 5].sum()),
        materialized=0,
        output=n_suffix * 8,
        rounds=int(statmat[:, 9].max()) if d else 0,
        dropped=int(statmat[:, 6].sum()),
    )
    stats = {
        "num_suffixes": n_suffix,
        "emitted": int(sa.shape[0]),
        "per_device_counts": statmat[:, 0].tolist(),
        "fetch_requests": int(statmat[:, 3].sum()),
        "iters": int(statmat[:, 2].max()),
        "rounds": fp.rounds,
        "dropped": fp.dropped,
        "retries": int(statmat[:, 7].sum()),
        "unresolved": int(statmat[:, 8].sum()),
    }
    return SAResult(suffix_array=sa, footprint=fp, stats=stats)


# ---------------------------------------------------------------------------
# Device-side index-set refinement (the out-of-core merge's device backend)
# ---------------------------------------------------------------------------


def _refiner_fn(
    idx_hi: jnp.ndarray,
    idx_lo: jnp.ndarray,
    reads_l: jnp.ndarray,
    lengths_l: jnp.ndarray,
    halo_l: jnp.ndarray,
    *,
    cfg: SAConfig,
    num_shards: int,
    rows_per_shard: int,
    row_len: int,
    stride_bits: int,
    cap: int,
    max_rounds: int,
    uniform_len: Optional[int],
    text_mode: bool,
    text_len: int,
):
    """Per-device body ranking an arbitrary suffix-index set (under shard_map).

    The device analogue of the host merge's ``_refine_sort``: each device
    holds a slice of the index set (padding slots carry ``idx_hi == -1``),
    fetches the depth-0 windows remotely via :func:`mget_window`, sample-sorts
    the resulting 16-byte records across the axis (equal keys colocate), and
    refines still-tied groups with the same loop as the pipeline reducer.

    ``cap`` is the per-device slice length.  Capacities are sized for zero
    drops: the record shuffle needs only ``cap`` per bucket (a device sends
    at most its ``cap`` input records), but the refinement loop runs *after*
    sample-sort colocation, where one device can hold up to ``d * cap`` tied
    records whose window requests may all target one owner shard — so the
    fetch capacity must be ``d * cap``.  No retry rounds occur and the
    result is deterministic in one pass.
    """
    d = num_shards
    k = cfg.prefix_len
    valid0 = idx_hi >= 0

    spec = StoreSpec(
        axis=AXIS,
        num_shards=d,
        rows_per_shard=rows_per_shard,
        row_len=row_len,
        request_capacity=d * cap,
    )
    if text_mode:
        store_local = jnp.concatenate([reads_l.reshape(-1), halo_l.reshape(-1)])
        store_local = store_local[:, None]
        row = jnp.where(valid0, idx_lo, 0)
        off = jnp.zeros_like(idx_lo)
    else:
        store_local = reads_l
        row, off = unpack_index(idx_hi, idx_lo, stride_bits)

    # depth-0 windows for the local slice (remote fetch: the indexes are
    # arbitrary, their tokens live on whichever device owns them)
    win, exh0, ok, fs0 = mget_window(store_local, row, off, valid0, spec, cfg)
    words = win if cfg.server_pack else encoding.pack_words(win, cfg)
    kh = jnp.where(valid0, words[:, 0], KEY_SENTINEL)
    kl = jnp.where(valid0, words[:, 1], KEY_SENTINEL)

    # sample-sort the records across the axis: equal initial keys colocate
    # (lex_bucket is strict-less-than), so all further refinement is local.
    rec = jnp.stack(
        [kh, kl,
         jnp.where(valid0, idx_hi, KEY_SENTINEL),
         jnp.where(valid0, idx_lo, KEY_SENTINEL),
         exh0.astype(jnp.int32)],
        axis=1,
    )
    s_hi, s_lo = sample_splitters(kh, kl, cfg.samples_per_shard, AXIS)
    bucket = jnp.where(valid0, lex_bucket(kh, kl, s_hi, s_lo), jnp.int32(d))
    buf, slot, _ = bucket_scatter(rec, bucket, d + 1, cap, KEY_SENTINEL)
    drop = jnp.sum(valid0 & (slot >= d * cap)).astype(jnp.int32)
    recv = exchange(buf[:d], AXIS).reshape(d * cap, 5)
    kh, kl, ih, il, exh_i = (recv[:, i] for i in range(5))
    kh, kl, ih, il, exh_i = lax.sort((kh, kl, ih, il, exh_i), num_keys=4)
    validr = ih != KEY_SENTINEL

    eq = jnp.concatenate(
        [jnp.array([False]), (kh[1:] == kh[:-1]) & (kl[1:] == kl[:-1])]
    )
    eq = eq & validr
    g = run_starts(eq)

    analytic = text_mode or (uniform_len is not None)
    if analytic:
        exhausted = _suffix_exhausted(
            ih, il, jnp.int32(1), text_mode=text_mode, text_len=text_len,
            uniform_len=uniform_len, stride_bits=stride_bits, k=k,
        )
    else:
        exhausted = exh_i > 0  # resolved by the depth-0 fetch flags
    exhausted = exhausted | ~validr

    g, ih, il, exhausted, depth, stats = _refine_tie_groups(
        g, ih, il, exhausted, store_local=store_local, spec=spec, cfg=cfg,
        analytic=analytic, text_mode=text_mode, text_len=text_len,
        uniform_len=uniform_len, stride_bits=stride_bits,
        hard_cap=2 * max_rounds + 8,
    )

    unresolved = jnp.sum(
        _tied(g) & ~exhausted & (ih != KEY_SENTINEL)
    ).astype(jnp.int32)
    count = jnp.sum(ih != KEY_SENTINEL).astype(jnp.int32)
    statvec = jnp.stack(
        [
            count,
            stats["fetch_requests"] + fs0.requests,
            stats["fetch_request_bytes"] + fs0.request_bytes,
            stats["fetch_response_bytes"] + fs0.response_bytes,
            stats["iters"] + 1,  # service rounds incl. the depth-0 fetch
            stats["retries"] + fs0.dropped + drop,
            unresolved,
            stats["max_depth"],
        ]
    )
    return ih, il, statvec[None, :]


class DeviceRefiner:
    """Device-resident ranking of arbitrary suffix-index sets.

    The out-of-core merge's ``merge_backend="device"`` seam: wherever the
    host merge would rank a batch of global suffix indexes with numpy
    (splitter pools, oversized merge buckets, text-mode boundary re-ranks),
    this class runs the same group-synchronous window-refinement loop
    TPU-resident under ``shard_map``, windows served by ``mget_window`` from
    the device-sharded corpus — the merge never leaves the accelerator for
    bucket ranking.

    Jitted refiner programs are cached per padded batch size (sizes round up
    to the next power of two per device, so a merge compiles O(log capacity)
    programs, not one per bucket).  Fetch-byte accounting accumulates across
    calls and is folded into the merge's ``merge_fetch_bytes``.
    """

    def __init__(self, corpus, cfg: SAConfig, lengths=None, mesh=None):
        self.cfg = cfg
        self.mesh = _flat_mesh(mesh)
        self.d = self.mesh.devices.size
        corpus = np.asarray(corpus, np.int32)
        self.info = plan(corpus.shape, cfg, self.d, lengths)
        data, lens, halo = _shard_inputs(corpus, lengths, cfg, self.d, self.info)
        sharding = NamedSharding(self.mesh, P(AXIS))
        self._data = jax.device_put(data, sharding)
        self._lens = jax.device_put(lens, sharding)
        self._halo = jax.device_put(halo, sharding)
        self._fns = {}
        # accounting (read by the superblock merge)
        self.requests = 0
        self.request_bytes = 0
        self.response_bytes = 0
        self.rounds = 0
        self.retries = 0
        self.peak_records = 0
        self.calls = 0

    def _fn(self, per_dev: int):
        fn = self._fns.get(per_dev)
        if fn is None:
            body = partial(
                _refiner_fn,
                cfg=self.cfg,
                num_shards=self.d,
                rows_per_shard=self.info["rows_per_shard"],
                row_len=self.info["row_len"],
                stride_bits=self.info["stride_bits"],
                cap=per_dev,
                max_rounds=self.info["max_rounds"],
                uniform_len=self.info["uniform_len"],
                text_mode=self.info["text_mode"],
                text_len=self.info["text_len"],
            )
            smapped = shard_map(
                body, mesh=self.mesh,
                in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(AXIS)),
                out_specs=(P(AXIS), P(AXIS), P(AXIS)),
                check_vma=not self.cfg.use_pallas,
            )
            fn = self._fns[per_dev] = jax.jit(smapped)
        return fn

    def refine(self, gidx: np.ndarray) -> np.ndarray:
        """Rank ``gidx`` (int64 global suffix indexes) in exact suffix order."""
        gidx = np.asarray(gidx, np.int64)
        m = gidx.shape[0]
        if m <= 1:
            return gidx.copy()
        per_dev = 1 << max(0, (-(-m // self.d) - 1)).bit_length()
        m_pad = per_dev * self.d
        ih = np.full(m_pad, -1, np.int32)
        il = np.full(m_pad, -1, np.int32)
        ih[:m] = (gidx >> WORD_BITS).astype(np.int32)
        il[:m] = (gidx & (WORD_MOD - 1)).astype(np.int32)
        out_ih, out_il, statmat = self._fn(per_dev)(
            ih, il, self._data, self._lens, self._halo
        )
        out_ih, out_il = np.asarray(out_ih), np.asarray(out_il)
        statmat = np.asarray(statmat)
        if int(statmat[:, 6].sum()) > 0 or int(statmat[:, 5].sum()) > 0:
            raise RuntimeError(
                "device refinement did not converge (unresolved ties/drops)"
            )
        self.calls += 1
        self.requests += int(statmat[:, 1].sum())
        self.request_bytes += int(statmat[:, 2].sum())
        self.response_bytes += int(statmat[:, 3].sum())
        self.rounds += int(statmat[:, 4].max())
        self.peak_records = max(self.peak_records, m)
        n_per = out_ih.shape[0] // self.d
        chunks = []
        for i in range(self.d):
            lo = i * n_per
            cnt = int(statmat[i, 0])
            chunks.append(global_index(out_ih[lo : lo + cnt], out_il[lo : lo + cnt]))
        out = np.concatenate(chunks) if chunks else np.zeros((0,), np.int64)
        assert out.shape[0] == m, (out.shape, m)
        return out


def refine_indices(
    corpus, gidx, cfg: SAConfig = SAConfig(), lengths=None, mesh=None
) -> np.ndarray:
    """One-shot convenience wrapper over :class:`DeviceRefiner`."""
    return DeviceRefiner(corpus, cfg, lengths=lengths, mesh=mesh).refine(gidx)
