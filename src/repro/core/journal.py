"""Append-only build journal: crash-safe progress records for the
out-of-core superblock build.

One JSON record per line, each carrying a ``crc`` of its own canonical
serialization, fsync'd at unit-of-recovery boundaries.  The journal lives
under ``SuperblockConfig.spill_dir`` next to the stable scratch directory;
``resume=True`` replays it on re-entry and skips every verified-complete
unit (see ``docs/fault_tolerance.md`` for the record format and resume
semantics).

Record types (``"t"``):

* ``begin`` — build fingerprint (corpus geometry + content signature + the
  plan shape).  A resume against a different corpus/plan is refused.
* ``stage`` — block ``i``'s corpus window was staged (observability only:
  staging is recomputed on resume).
* ``block`` — block ``i``'s sorted run is durably spilled: run filename,
  content crc, row count, and the block's build stats/footprint
  contributions, so a resumed build reconstructs phase-2 state without
  re-running the block.  Always fsync'd — this is the unit of recovery.
* ``emit`` — merge emission watermark (rows emitted so far).  Batched
  fsync: the merge is redone wholesale on resume, the watermark exists for
  observability and torn-tail tolerance testing.

Failure semantics on replay: a torn **final** record (the crash landed
mid-append) is dropped and its unit simply replays; a corrupt **interior**
record is a :class:`~repro.core.integrity.CorruptionError` — the journal
itself is an artifact, and silently skipping verified history could resume
against the wrong plan.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.integrity import (
    CorruptionError,
    crc32_array,
    crc32_bytes,
    fsync_dir,
)

__all__ = ["BuildJournal", "verify_spilled_run"]

JOURNAL_NAME = "build.journal"

# non-durable records (stage/emit) still hit the disk at this cadence so a
# crash loses at most a bounded window of observability records
_SYNC_EVERY = 64


def _coerce(x):
    """json default hook: numpy scalars -> python scalars; anything else
    degrades to ``str`` (stats payloads are observability, and ``str`` is
    deterministic, so the replayed canonical form still matches the crc)."""
    if isinstance(x, np.integer):
        return int(x)
    if isinstance(x, np.floating):
        return float(x)
    if isinstance(x, np.bool_):
        return bool(x)
    if isinstance(x, np.ndarray):
        return x.tolist()
    return str(x)


def _canon(rec: Dict[str, Any]) -> str:
    """Canonical serialization the crc is computed over.  Write and replay
    must agree, so the form is fully deterministic: sorted keys, no
    whitespace, numpy coerced to the same natives json parses back."""
    return json.dumps(rec, sort_keys=True, separators=(",", ":"),
                      default=_coerce)


class BuildJournal:
    """Writer/replayer for the build journal.  Main-thread only: records
    are appended at unit *completion* (after the async spill write is observed
    durable via ``PipelineTask.done()``), so no locking is needed and the
    threading discipline (salint SAL008/SAL009) holds.
    """

    VERSION = 1

    def __init__(self, path: str):
        self.path = path
        self._f = None
        self._unsynced = 0
        self.appended = 0

    # -- writing ----------------------------------------------------------

    def open(self) -> "BuildJournal":
        self._f = open(self.path, "a", encoding="utf-8")
        return self

    def append(self, rec: Dict[str, Any], durable: bool = True) -> None:
        """Append one record (``crc`` stamped here).  ``durable=True``
        fsyncs before returning — the record's unit is then recoverable."""
        assert self._f is not None, "journal not open"
        body = _canon(rec)
        rec = dict(rec)
        rec["crc"] = crc32_bytes(body.encode("utf-8"))
        self._f.write(_canon(rec) + "\n")
        self._f.flush()
        self.appended += 1
        if durable:
            os.fsync(self._f.fileno())
            self._unsynced = 0
        else:
            self._unsynced += 1
            if self._unsynced >= _SYNC_EVERY:
                os.fsync(self._f.fileno())
                self._unsynced = 0

    def close(self) -> None:
        if self._f is not None:
            self._f.flush()
            os.fsync(self._f.fileno())
            self._f.close()
            self._f = None

    def finalize(self) -> None:
        """Successful build end: the journal has served its purpose —
        remove it (durably) so a later build in the same dir starts clean."""
        self.close()
        if os.path.exists(self.path):
            os.unlink(self.path)
            fsync_dir(os.path.dirname(os.path.abspath(self.path)))

    # -- replay -----------------------------------------------------------

    @staticmethod
    def load(path: str) -> List[Dict[str, Any]]:
        """Replay the journal into validated records.

        A torn final append (truncated line / missing newline) is dropped —
        its unit replays.  Any other validation failure raises
        :class:`CorruptionError` naming the record.
        """
        if not os.path.exists(path):
            return []
        with open(path, "rb") as f:
            raw = f.read().decode("utf-8", errors="replace")
        lines = raw.split("\n")
        tail_torn = bool(lines) and lines[-1] != ""  # no trailing newline
        if lines and lines[-1] == "":
            lines.pop()
        records: List[Dict[str, Any]] = []
        for idx, line in enumerate(lines):
            rec: Optional[Dict[str, Any]] = None
            ok = False
            try:
                parsed = json.loads(line)
                if isinstance(parsed, dict) and "crc" in parsed:
                    crc = parsed.pop("crc")
                    ok = crc == crc32_bytes(_canon(parsed).encode("utf-8"))
                    rec = parsed
            except ValueError:
                ok = False
            if not ok:
                if idx == len(lines) - 1 and tail_torn:
                    break  # torn final append: drop, unit replays
                raise CorruptionError(
                    f"build journal record {idx}", path=path)
            records.append(rec)
        return records


def verify_spilled_run(path: str, expected_crc: int,
                       artifact: str) -> np.ndarray:
    """Load a journaled spilled run and verify its content crc.

    Returns the read-only memmap on success.  Any load failure or crc
    mismatch is a :class:`CorruptionError` naming the run — a journaled
    run that exists but does not verify must never be silently rebuilt
    (the journal said it was durable; the bytes disagree).
    """
    try:
        mm = np.load(path, mmap_mode="r")
    except (ValueError, OSError, EOFError) as e:
        raise CorruptionError(artifact, detail=f"unreadable: {e}",
                              path=path) from e
    got = crc32_array(mm)
    if got != expected_crc:
        raise CorruptionError(
            artifact,
            detail=f"crc 0x{got:08x} != journaled 0x{expected_crc:08x}",
            path=path)
    return mm
