"""Record / index types shared by the suffix-array pipelines.

A suffix is identified by a **global index** packed the way the paper packs
``sequence_number * 1000 + offset`` into a ``long`` — except we use a power of
two stride (shifts instead of division; documented adaptation in DESIGN.md §2)
and split the 62-bit quantity into two non-negative int31 words so the whole
record stays int32 (JAX x64 stays off, matching TPU-native dtypes).

Record layout (all int32, 16 bytes — identical width to the paper's long+long):

    [key_hi, key_lo, idx_hi, idx_lo]

``key_hi/key_lo`` hold the packed K-token prefix (order-preserving); sorting
lexicographically by (key_hi, key_lo, idx_hi, idx_lo) with
``lax.sort(num_keys=4)`` is exactly the paper's reducer sort with stable
index tie-breaking.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

# Sentinel key value: sorts after every real key (keys are < 2^31 - 1).
KEY_SENTINEL = np.int32(np.iinfo(np.int32).max)
# int31 word size used for index packing.
WORD_BITS = 31
WORD_MOD = 1 << WORD_BITS


def pack_index(read_id, offset, stride_bits: int):
    """(read_id, offset) -> (idx_hi, idx_lo) int32 words.

    gidx = read_id << stride_bits | offset, split into hi/lo int31 words.
    Works on numpy or jnp arrays.
    """
    xp = jnp if isinstance(read_id, jnp.ndarray) else np
    read_id = read_id.astype(xp.int64) if xp is np else read_id.astype(jnp.int32)
    if xp is np:
        gidx = (read_id.astype(np.int64) << stride_bits) | offset.astype(np.int64)
        return (
            (gidx >> WORD_BITS).astype(np.int32),
            (gidx & (WORD_MOD - 1)).astype(np.int32),
        )
    # jnp path: avoid int64 (x64 disabled).  read_id < 2^31; offset < 2^stride.
    # hi word = read_id >> (31 - stride_bits); lo = low bits of read_id
    # concatenated with offset.
    lo_bits = WORD_BITS - stride_bits
    hi = read_id >> lo_bits
    lo = ((read_id & ((1 << lo_bits) - 1)) << stride_bits) | offset
    return hi.astype(jnp.int32), lo.astype(jnp.int32)


def unpack_index(idx_hi, idx_lo, stride_bits: int):
    """(idx_hi, idx_lo) -> (read_id, offset).  numpy or jnp."""
    xp = jnp if isinstance(idx_hi, jnp.ndarray) else np
    lo_bits = WORD_BITS - stride_bits
    offset = idx_lo & ((1 << stride_bits) - 1)
    read_lo = idx_lo >> stride_bits
    read_id = (idx_hi << lo_bits) | read_lo
    return read_id.astype(xp.int32), offset.astype(xp.int32)


def global_index(idx_hi: np.ndarray, idx_lo: np.ndarray) -> np.ndarray:
    """Numpy only: combine words into one int64 global index."""
    return (idx_hi.astype(np.int64) << WORD_BITS) | idx_lo.astype(np.int64)


@dataclass
class Footprint:
    """Data-store footprint (paper §III): deterministic byte accounting.

    The paper's disk/HDFS/network categories map to HBM/ICI (DESIGN.md §2):

    * ``store_put``       — bytes of raw data resident in the in-memory store
                            (paper: Redis memory, incl. metadata overhead)
    * ``shuffle``         — bytes exchanged in the record all_to_all
                            (paper: MR shuffle)
    * ``fetch_request``   — bytes of index requests to the store
    * ``fetch_response``  — bytes of suffix windows returned (mgetsuffix)
    * ``materialized``    — peak bytes of suffix payloads materialized outside
                            the store (paper: map-side local write of suffixes)
    * ``output``          — bytes of the final SA
    """

    input: int = 0
    store_put: int = 0
    shuffle: int = 0
    fetch_request: int = 0
    fetch_response: int = 0
    materialized: int = 0
    output: int = 0
    rounds: int = 0
    dropped: int = 0
    # out-of-core accounting (core/superblock.py): number of superblocks the
    # build was split into, and the peak number of 16-byte suffix records any
    # single run (per-block pipeline, merge bucket, splitter batch) held at
    # once.  superblocks == 1 <=> single-pass in-core build.
    superblocks: int = 1
    peak_records: int = 0
    # store-layer residency (PR 3): peak bytes of the store working set —
    # backend chunk cache + merge frontier (cursor windows) — during an
    # out-of-core build.  With the chunked file backend this is bounded by
    # SuperblockConfig.cache_budget_bytes; 0 = not measured (single-pass).
    peak_resident_bytes: int = 0

    def total_traffic(self) -> int:
        return self.shuffle + self.fetch_request + self.fetch_response

    def units(self) -> dict:
        """Everything normalized to input size = 1 unit (paper's tables)."""
        ref = max(self.input, 1)
        return {
            "input": 1.0,
            "store_put": self.store_put / ref,
            "shuffle": self.shuffle / ref,
            "fetch_request": self.fetch_request / ref,
            "fetch_response": self.fetch_response / ref,
            "materialized": self.materialized / ref,
            "output": self.output / ref,
            "rounds": self.rounds,
            "dropped": self.dropped,
            "superblocks": self.superblocks,
            "peak_record_bytes": self.peak_records * 16 / ref,
            "peak_resident": self.peak_resident_bytes / ref,
        }


@dataclass
class SAResult:
    """Result of a suffix-array build."""

    # (n,) int64 global suffix indexes in sorted suffix order (numpy, host)
    suffix_array: np.ndarray
    footprint: Footprint
    stats: dict
    # (n,) int64 adjacent-pair LCP array (lcp[i] = LCP(sa[i-1], sa[i]),
    # lcp[0] = 0) when the build was asked for it (SuperblockConfig.emit_lcp
    # / repro.core.lcp); None otherwise
    lcp: Optional[np.ndarray] = None

    def read_offset(self, stride_bits: int) -> Tuple[np.ndarray, np.ndarray]:
        sa = self.suffix_array
        return (sa >> stride_bits).astype(np.int64), (
            sa & ((1 << stride_bits) - 1)
        ).astype(np.int64)
