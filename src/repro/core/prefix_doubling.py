"""Beyond-paper variant: distributed prefix doubling (Manber–Myers).

The paper's tie-break loop refines K tokens per round — O(maxLCP / K) rounds,
which degenerates on highly repetitive text (the paper's own "ATATATAT" GC
anecdote, §III).  Prefix doubling converges in O(log n) rounds instead, and —
the point of this module — it needs *no new machinery*: the "in-memory data
store" abstraction now stores **ranks** instead of raw tokens, and every round
is (a) one ``mget_scalar`` (rank[pos+h] — exactly an mgetsuffix-shaped batched
query), (b) one record shuffle of the same 16-byte records, (c) one
``scatter_update`` write-back.  "Keep only the raw data in place" generalizes
to "keep only the *authoritative array* in place".

Rank convention: rank(suffix) = global position of the first member of its
still-tied run (monotone, comparable, unique iff fully resolved) — the
standard MM formulation, computed distributedly with an O(D) cross-device
run-chaining pass on all_gathered per-device summaries.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import SAConfig
from repro.core import encoding
from repro.core.distributed import (
    bucket_scatter,
    pvary,
    exchange,
    lex_bucket,
    run_starts,
    sample_splitters,
    shard_map,
)
from repro.core.pipeline import AXIS, _flat_mesh, _shard_inputs, plan
from repro.core.store import StoreSpec, mget_scalar, scatter_update, token_bytes
from repro.core.types import KEY_SENTINEL, Footprint, SAResult


def _global_sort3(rank, rank2, pos, d, cap, samples):
    """Sample-sort (rank, rank2, pos) records across the axis.

    Returns sorted (rank, rank2, pos) of length d*cap per device + drop count.
    Equal (rank, rank2) pairs colocate (lex_bucket is strict-less-than).
    Sentinel padding records go to a local dump bucket (never shipped, never
    counted as drops — they are just regenerated as fill on the receive side).
    """
    valid = rank != KEY_SENTINEL
    s1, s2 = sample_splitters(
        jnp.where(valid, rank, KEY_SENTINEL), jnp.where(valid, rank2, KEY_SENTINEL),
        samples, AXIS,
    )
    bucket = jnp.where(valid, lex_bucket(rank, rank2, s1, s2), jnp.int32(d))
    rec = jnp.stack([rank, rank2, pos], axis=1)
    buf, slot, _ = bucket_scatter(rec, bucket, d + 1, cap, KEY_SENTINEL)
    drop = jnp.sum(valid & (slot >= d * cap)).astype(jnp.int32)
    recv = exchange(buf[:d], AXIS).reshape(d * cap, 3)
    r1, r2, p = lax.sort((recv[:, 0], recv[:, 1], recv[:, 2]), num_keys=3)
    return r1, r2, p, drop


def _global_rerank(k1, k2, d):
    """Global run-start ranks for device-locally-sorted (k1, k2) keys.

    Sentinel records (k1 == KEY_SENTINEL) must be sorted last.  Returns
    (rank, tied, count): rank[i] = gpos of the first member of i's run
    (KEY_SENTINEL for sentinel slots); tied[i] = run size > 1.
    """
    m = k1.shape[0]
    me = lax.axis_index(AXIS)
    valid = k1 != KEY_SENTINEL
    c = jnp.sum(valid).astype(jnp.int32)

    counts = lax.all_gather(c, AXIS)  # (D,)
    offs = jnp.cumsum(counts) - counts  # exclusive
    o = offs[me]
    gpos = o + jnp.arange(m, dtype=jnp.int32)

    prev_ok = jnp.concatenate([jnp.array([False]), valid[:-1]])
    eq = jnp.concatenate(
        [jnp.array([False]), (k1[1:] == k1[:-1]) & (k2[1:] == k2[:-1])]
    )
    eq = eq & valid & prev_ok
    ls = run_starts(eq)  # local index of run start

    # --- per-device summaries ------------------------------------------
    last = jnp.maximum(c - 1, 0)
    fk1, fk2 = k1[0], k2[0]
    lk1 = k1[last]
    lk2 = k2[last]
    lrs = ls[last]  # local run start of last valid record
    has = c > 0
    g_fk1 = lax.all_gather(jnp.where(has, fk1, KEY_SENTINEL), AXIS)
    g_fk2 = lax.all_gather(jnp.where(has, fk2, KEY_SENTINEL), AXIS)
    g_lk1 = lax.all_gather(jnp.where(has, lk1, KEY_SENTINEL), AXIS)
    g_lk2 = lax.all_gather(jnp.where(has, lk2, KEY_SENTINEL), AXIS)
    g_lrs = lax.all_gather(lrs, AXIS)
    g_has = lax.all_gather(has, AXIS)

    # --- chain run starts across devices (O(D), replicated compute) ----
    def chain(j, carry):
        S, pk1, pk2, pstart, phas = carry
        oj = offs[j]
        cont = phas & g_has[j] & (g_fk1[j] == pk1) & (g_fk2[j] == pk2)
        sj = jnp.where(cont, pstart, oj)
        all_one = g_lrs[j] == 0  # device j is a single run
        new_start = jnp.where(all_one, sj, oj + g_lrs[j])
        S = S.at[j].set(sj)
        pk1 = jnp.where(g_has[j], g_lk1[j], pk1)
        pk2 = jnp.where(g_has[j], g_lk2[j], pk2)
        pstart = jnp.where(g_has[j], new_start, pstart)
        phas = phas | g_has[j]
        return (S, pk1, pk2, pstart, phas)

    d_sz = counts.shape[0]
    pv = lambda x: pvary(x, AXIS)
    S0 = pv(jnp.zeros((d_sz,), jnp.int32))
    S, *_ = lax.fori_loop(
        0, d_sz, chain,
        (S0, pv(jnp.int32(KEY_SENTINEL)), pv(jnp.int32(KEY_SENTINEL)),
         pv(jnp.int32(0)), pv(jnp.asarray(False))),
    )

    rank = jnp.where(ls == 0, S[me], o + ls)
    rank = jnp.where(valid, rank, KEY_SENTINEL)

    # tied: run of size > 1, including cross-device continuation
    nxt_eq = jnp.concatenate([eq[1:], jnp.array([False])])
    # my last record continues into next device?  equivalently next device's
    # first record equals mine — detect via gathered firsts of device me+1
    nk1 = jnp.where(me + 1 < d_sz, g_fk1[jnp.minimum(me + 1, d_sz - 1)], KEY_SENTINEL)
    nk2 = jnp.where(me + 1 < d_sz, g_fk2[jnp.minimum(me + 1, d_sz - 1)], KEY_SENTINEL)
    cont_out = (k1 == nk1) & (k2 == nk2) & valid
    is_last = jnp.arange(m) == last
    tied = eq | nxt_eq | (is_last & cont_out & has)
    # first record continuing from previous device is also tied
    first_cont = (
        (jnp.arange(m) == 0) & valid & (rank != gpos)
    )
    tied = tied | first_cont
    return rank, tied & valid, c


def _device_fn(
    text_l, lengths_l, halo_l, *, cfg: SAConfig, num_shards, rows_per_shard,
    shuffle_cap, fetch_cap, text_len, max_rounds,
):
    d = num_shards
    k = cfg.prefix_len
    me = lax.axis_index(AXIS)

    # --- initial records from K-token prefix keys ----------------------
    flat = jnp.concatenate([text_l.reshape(-1), halo_l.reshape(-1)])
    rec = encoding.make_records_text(
        flat, cfg, pos_base=me * rows_per_shard, n_emit=rows_per_shard
    )
    pos0 = jnp.arange(rows_per_shard, dtype=jnp.int32) + me * rows_per_shard
    valid0 = pos0 < text_len
    kh = jnp.where(valid0, rec[:, 0], KEY_SENTINEL)
    kl = jnp.where(valid0, rec[:, 1], KEY_SENTINEL)
    pos = jnp.where(valid0, rec[:, 3], KEY_SENTINEL)

    r1, r2, p, drop0 = _global_sort3(kh, kl, pos, d, shuffle_cap, cfg.samples_per_shard)
    rank, tied, c = _global_rerank(r1, r2, d)

    spec = StoreSpec(
        axis=AXIS, num_shards=d, rows_per_shard=rows_per_shard, row_len=1,
        request_capacity=fetch_cap,
    )
    store0 = jnp.zeros((rows_per_shard,), jnp.int32)
    store, dropw = scatter_update(store0, p, rank, p != KEY_SENTINEL, spec)

    zero = pvary(jnp.int32(0), AXIS)
    stats0 = dict(
        rounds=zero, shuffles_bytes=zero, fetch_bytes=zero,
        drops=drop0 + dropw + zero,
    )

    def cond(carry):
        rank, p, store, h, n_tied, stats = carry
        return (lax.psum(n_tied, AXIS) > 0) & (stats["rounds"] < max_rounds)

    def body(carry):
        rank, p, store, h, n_tied, stats = carry
        active = p != KEY_SENTINEL
        r2_new, dropf = mget_scalar(store, p + h, active & (p + h < text_len), spec, fill=-1)
        r2_new = jnp.where(active & (p + h < text_len), r2_new, -1)
        r1s, r2s, ps, drops = _global_sort3(
            rank, jnp.where(active, r2_new, KEY_SENTINEL), p, d, shuffle_cap,
            cfg.samples_per_shard,
        )
        new_rank, tied, c = _global_rerank(r1s, r2s, d)
        store, dropw = scatter_update(store, ps, new_rank, ps != KEY_SENTINEL, spec)
        n_tied = jnp.sum(tied).astype(jnp.int32)
        stats = dict(
            rounds=stats["rounds"] + 1,
            shuffles_bytes=stats["shuffles_bytes"] + c * 12,
            fetch_bytes=stats["fetch_bytes"] + jnp.sum(active).astype(jnp.int32) * 8,
            drops=stats["drops"] + dropf + drops + dropw,
        )
        return (new_rank, ps, store, h * 2, n_tied, stats)

    n_tied0 = jnp.sum(tied).astype(jnp.int32)
    rank, p, store, h, n_tied, stats = lax.while_loop(
        cond, body,
        (rank, p, store, pvary(jnp.int32(k), AXIS), n_tied0, stats0),
    )

    count = jnp.sum(p != KEY_SENTINEL).astype(jnp.int32)
    statvec = jnp.stack(
        [count, c * 0 + jnp.sum(pos != KEY_SENTINEL).astype(jnp.int32),
         stats["rounds"], stats["shuffles_bytes"], stats["fetch_bytes"],
         stats["drops"], n_tied]
    )
    return p, statvec[None, :]


def build_suffix_array_doubling(
    text, cfg: SAConfig = SAConfig(), mesh: Optional[Mesh] = None,
) -> SAResult:
    """Prefix-doubling SA for long texts (beyond-paper optimized mode)."""
    text = np.asarray(text, np.int32)
    assert text.ndim == 1, "doubling mode is for long-text corpora"
    mesh = _flat_mesh(mesh)
    d = mesh.devices.size
    info = plan(text.shape, cfg, d)
    data, lens, halo = _shard_inputs(text, None, cfg, d, info)
    sharding = NamedSharding(mesh, P(AXIS))
    data = jax.device_put(data, sharding)
    lens = jax.device_put(lens, sharding)
    halo = jax.device_put(halo, sharding)

    n = text.shape[0]
    max_rounds = int(math.ceil(math.log2(max(n, 2)))) + 2
    slack = cfg.shuffle_slack
    for _attempt in range(7):
        # capacity per destination bucket
        shuffle_cap = max(1, int(math.ceil(info["rows_per_shard"] * slack / d)))
        fetch_cap = max(1, int(math.ceil(d * shuffle_cap * slack / d)))
        fn = partial(
            _device_fn, cfg=cfg, num_shards=d,
            rows_per_shard=info["rows_per_shard"], shuffle_cap=shuffle_cap,
            fetch_cap=fetch_cap, text_len=n, max_rounds=max_rounds,
        )
        smapped = shard_map(
            fn, mesh=mesh, in_specs=(P(AXIS), P(AXIS), P(AXIS)),
            out_specs=(P(AXIS), P(AXIS)),
        )
        p, statmat = jax.jit(smapped)(data, lens, halo)
        p, statmat = np.asarray(p), np.asarray(statmat)
        if statmat[:, 5].sum() == 0 and statmat[:, 6].sum() == 0:
            break
        slack *= 2  # host-level adaptive retry (two-phase planning fallback)

    per_dev = p.shape[0] // d
    chunks = []
    for i in range(d):
        lo = i * per_dev
        cnt = int(statmat[i, 0])
        chunks.append(p[lo : lo + cnt].astype(np.int64))
    sa = np.concatenate(chunks)

    tb = token_bytes(cfg.vocab_size)
    fp = Footprint(
        input=n * tb,
        store_put=n * tb + n * 4,  # corpus + rank store
        shuffle=int(statmat[:, 3].sum()),
        fetch_request=int(statmat[:, 4].sum()),
        fetch_response=int(statmat[:, 4].sum()) // 2,
        materialized=0,
        output=n * 8,
        rounds=int(statmat[:, 2].max()),
        dropped=int(statmat[:, 5].sum()),
    )
    stats = {
        "num_suffixes": n,
        "emitted": int(sa.shape[0]),
        "rounds": fp.rounds,
        "dropped": fp.dropped,
        "unresolved": int(statmat[:, 6].sum()),
    }
    return SAResult(suffix_array=sa, footprint=fp, stats=stats)
