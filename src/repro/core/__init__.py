"""repro.core — the paper's contribution: scalable distributed suffix-array
construction with an in-memory data store (see DESIGN.md)."""
from repro.core.types import Footprint, SAResult, KEY_SENTINEL
from repro.core.pipeline import build_suffix_array
from repro.core.superblock import (
    build_suffix_array_auto,
    build_suffix_array_superblock,
    plan_superblocks,
)

__all__ = [
    "Footprint",
    "SAResult",
    "KEY_SENTINEL",
    "build_suffix_array",
    "build_suffix_array_auto",
    "build_suffix_array_superblock",
    "plan_superblocks",
]
