"""Version-compat shims shared by the Pallas kernels.

``jax.typeof`` / vma-typed outputs are post-0.5 jax features; earlier
releases have no varying/replicated type distinction, so the shims degrade
to "no vma" there instead of crashing at call time.
"""
from __future__ import annotations

import jax


def vma_of(*xs) -> frozenset:
    """Union of the operands' varying-manual-axes (empty on old jax)."""
    typeof = getattr(jax, "typeof", None)
    out = frozenset()
    if typeof is None:
        return out
    for x in xs:
        out = out | (getattr(typeof(x), "vma", frozenset()) or frozenset())
    return out


def out_struct(shape, dtype, vma=frozenset()):
    """``jax.ShapeDtypeStruct`` with vma when the jax version supports it."""
    try:
        return jax.ShapeDtypeStruct(shape, dtype, vma=vma)
    except TypeError:
        return jax.ShapeDtypeStruct(shape, dtype)
