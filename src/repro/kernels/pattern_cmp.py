"""Pallas kernel: batched masked suffix-vs-pattern window compare (the query
engine's one device compare per binary-search round).

One row per live query: ``sfx`` is the suffix's K-token store window at the
query's current depth, ``pat`` the pattern's K-token slice at the same depth
(0-padded past the pattern end), and ``[start, stop)`` the in-window token
range still undecided — ``start`` comes from the Manber–Myers L/R bound (the
tokens before it are already known equal), ``stop`` from the pattern's
remaining length.  The kernel reports, per row,

    cmp     -1 / 0 / +1 : suffix <, ==, > pattern over [start, stop)
    matched             : tokens matched before the first mismatch

``cmp == 0`` means the whole range matched: the caller either declares the
pattern found (range reached the pattern end) or advances one window level.
A suffix ending inside the window compares via its padding ``0`` against a
real pattern token (>= 1), yielding ``-1`` — exactly the store's suffix
order, so no end-of-suffix special case exists here.

Pure VPU work (iota masks + where + row min-reduce; no MXU, no dynamic
addressing), gridded over blocks of query rows like ``merge_path``; the
value-at-first-mismatch gather is a one-hot masked sum, not an index load.
Padding rows carry ``start == stop == 0`` and fold to ``cmp = 0``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.kernels.compat import out_struct, vma_of as _vma


def _kernel(sfx_ref, pat_ref, start_ref, stop_ref, out_ref):
    sfx = sfx_ref[...]  # (B, K) int32 suffix windows
    pat = pat_ref[...]  # (B, K) int32 pattern slices
    start = start_ref[...]  # (B,) compare-from token offset
    stop = stop_ref[...]  # (B,) compare-to token offset (exclusive)
    b, k = sfx.shape
    iota = lax.broadcasted_iota(jnp.int32, (b, k), 1)
    in_rng = (iota >= start[:, None]) & (iota < stop[:, None])
    eq = jnp.where(in_rng, sfx == pat, True)
    # first in-range mismatch position; rows with none fold to `stop`
    first = jnp.min(jnp.where(eq, stop[:, None], iota), axis=1)
    matched = first - start
    hit = iota == first[:, None]  # one-hot value gather at the mismatch
    sv = jnp.sum(jnp.where(hit, sfx, 0), axis=1)
    pv = jnp.sum(jnp.where(hit, pat, 0), axis=1)
    neq = first < stop
    cmp = jnp.where(neq & (sv < pv), -1, jnp.where(neq & (sv > pv), 1, 0))
    out_ref[...] = jnp.stack(
        [cmp.astype(jnp.int32), matched.astype(jnp.int32)], axis=1)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def pattern_cmp(sfx: jnp.ndarray, pat: jnp.ndarray, start: jnp.ndarray,
                stop: jnp.ndarray, block: int = 256,
                interpret: bool = True) -> jnp.ndarray:
    """(B, K) suffix/pattern windows + (B,) [start, stop) -> (B, 2) int32
    ``[cmp, matched]`` rows (see module docstring)."""
    n, k = sfx.shape
    nblocks = max(1, -(-n // block))
    pad = nblocks * block - n
    sfx_p = jnp.pad(jnp.asarray(sfx, jnp.int32), ((0, pad), (0, 0)))
    pat_p = jnp.pad(jnp.asarray(pat, jnp.int32), ((0, pad), (0, 0)))
    start_p = jnp.pad(jnp.asarray(start, jnp.int32), (0, pad))
    stop_p = jnp.pad(jnp.asarray(stop, jnp.int32), (0, pad))
    out = pl.pallas_call(
        _kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block, k), lambda i: (i, 0)),
            pl.BlockSpec((block, k), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block, 2), lambda i: (i, 0)),
        out_shape=out_struct((nblocks * block, 2), jnp.int32,
                             vma=_vma(sfx, pat)),
        interpret=interpret,
    )(sfx_p, pat_p, start_p, stop_p)
    return out[:n]
