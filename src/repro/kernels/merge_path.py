"""Pallas kernel: merge-path ranking of sorted-run key tiles (device k-way
merge, the out-of-core merge's bucket engine).

Given one tile of merge candidates — the buffered frontiers of k sorted runs,
each candidate a row of order-preserving packed key words (window words from
``prefix_pack`` packing + the two int31 global-index words as the final
tiebreak) — compute every candidate's **output rank** in the merged order.

This is the classic GPU merge-path formulation turned inside out: merge-path
binary-searches each output diagonal for its (run, offset) crossing; since the
tiebreak words make rows strictly unique, the crossing of element ``e``'s
diagonal is exactly the number of candidates with a smaller key, so

    rank(e) = #{c : key(c) < key(e)}

and the interleaved output permutation is ``out[rank(e)] = e``.  Every rank is
independent — zero sequential dependence, pure VPU compare/accumulate work (no
MXU, no dynamic addressing), which is why this replaces the host heap walk.

Grid: one step per block of B candidate rows; the full key tile stays resident
in VMEM (C x W int32 — a merge tile is a few thousand rows of a handful of
words, well under VMEM).  Padding rows carry ``jnp.iinfo(int32).max`` in every
word: they sort after all real keys (real words are int31, index words int31)
and their ranks land past ``n`` where the caller discards them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import out_struct, vma_of as _vma


def _kernel(blk_ref, all_ref, out_ref, *, words):
    blk = blk_ref[...]  # (B, W) this block's candidate keys
    full = all_ref[...]  # (C, W) every candidate key in the tile
    b = blk.shape[0]
    lt = jnp.zeros((b, full.shape[0]), jnp.bool_)
    eq = jnp.ones((b, full.shape[0]), jnp.bool_)
    for w in range(words):  # static: W is a handful of words
        a = blk[:, w][:, None]
        c = full[:, w][None, :]
        lt = lt | (eq & (c < a))
        eq = eq & (c == a)
    out_ref[...] = jnp.sum(lt.astype(jnp.int32), axis=1)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def merge_path_ranks(keys: jnp.ndarray, block: int = 256,
                     interpret: bool = True) -> jnp.ndarray:
    """keys (C, W) int32 rows, strictly unique -> (C,) int32 output ranks.

    Rows must be strictly ordered by lexicographic word compare (the caller
    appends the packed global-index words, which are unique); the result is
    a permutation of ``0..C-1``.
    """
    n, w = keys.shape
    nblocks = -(-n // block)
    pad = nblocks * block - n
    big = jnp.iinfo(jnp.int32).max
    padded = jnp.pad(keys, ((0, pad), (0, 0)), constant_values=big)
    ranks = pl.pallas_call(
        functools.partial(_kernel, words=w),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block, w), lambda i: (i, 0)),
            pl.BlockSpec((nblocks * block, w), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=out_struct((nblocks * block,), jnp.int32, vma=_vma(keys)),
        interpret=interpret,
    )(padded, padded)
    return ranks[:n]
