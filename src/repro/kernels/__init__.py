"""Pallas kernels: one module per kernel, each paired with a bit-exact ref.

``KERNEL_REGISTRY`` is the machine-checked pairing (salint rule SAL001):
every kernel module in this package must be registered here with its public
dispatch op (``repro.kernels.ops``) and its reference oracle
(``repro.kernels.ref``), and every registered kernel must be exercised by
the ``tests/test_kernels.py`` sweep.  An unregistered kernel module — or a
registry entry whose reference does not exist — fails
``python -m tools.salint`` and the registry sweep test.
"""
from __future__ import annotations

import os
from typing import Dict, List, NamedTuple


class KernelSpec(NamedTuple):
    """One Pallas kernel's registration."""

    module: str  # kernel module basename under repro/kernels/
    op: str  # public dispatch callable in repro.kernels.ops
    ref: str  # bit-exact oracle callable in repro.kernels.ref


# Keys are kernel module basenames.  salint SAL001 statically checks that
# this dict covers every kernel module on disk, that each ``ref`` is defined
# in kernels/ref.py, and that tests/test_kernels.py sweeps the registry.
KERNEL_REGISTRY: Dict[str, KernelSpec] = {
    "prefix_pack": KernelSpec("prefix_pack", "prefix_pack", "prefix_pack_ref"),
    "window_gather": KernelSpec(
        "window_gather", "window_gather", "window_gather_ref"),
    "bucket_hist": KernelSpec("bucket_hist", "bucket_hist", "bucket_hist_ref"),
    "bitonic_sort": KernelSpec(
        "bitonic_sort", "bitonic_sort_tiles", "bitonic_sort_tiles_ref"),
    "merge_path": KernelSpec(
        "merge_path", "merge_path_ranks", "merge_path_ranks_ref"),
    "pattern_cmp": KernelSpec("pattern_cmp", "pattern_cmp", "pattern_cmp_ref"),
}

# Support modules that are not kernels themselves: the jit'd dispatch layer,
# the reference oracles, the jax-version compat shims, and this registry.
SUPPORT_MODULES = frozenset({"__init__", "ops", "ref", "compat"})


def kernel_modules() -> List[str]:
    """Kernel module basenames present on disk (registry ground truth)."""
    here = os.path.dirname(__file__)
    return sorted(
        f[:-3]
        for f in os.listdir(here)
        if f.endswith(".py") and f[:-3] not in SUPPORT_MODULES
    )
