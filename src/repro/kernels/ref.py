"""Pure-jnp oracles for every Pallas kernel (the ref the tests compare to)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.config import SAConfig
from repro.core import encoding


def prefix_pack_ref(tokens: jnp.ndarray, cfg: SAConfig) -> jnp.ndarray:
    """tokens (N,) -> keys (N, key_words); window i = tokens[i:i+K] 0-padded."""
    n = tokens.shape[0]
    k = cfg.prefix_len
    padded = jnp.pad(tokens, (0, k))
    cols = jnp.arange(n)[:, None] + jnp.arange(k)[None, :]
    return encoding.pack_words(padded[cols], cfg)


def window_gather_ref(corpus, rows, offs, k):
    return encoding.window_at(corpus, rows, offs, k)


def bucket_hist_ref(key_hi, key_lo, split_hi, split_lo):
    gt = (key_hi[:, None] > split_hi[None, :]) | (
        (key_hi[:, None] == split_hi[None, :]) & (key_lo[:, None] > split_lo[None, :])
    )
    bucket = jnp.sum(gt.astype(jnp.int32), axis=1)
    hist = jnp.bincount(bucket, length=split_hi.shape[0] + 1)
    return bucket, hist


def merge_path_ranks_ref(keys: jnp.ndarray) -> jnp.ndarray:
    """keys (C, W) int32 unique rows -> (C,) output ranks (merge-path oracle).

    rank(e) = number of rows lexicographically smaller than row e; with
    strictly-unique rows (the index tiebreak words) this is the interleaved
    output permutation of the k-way merge.
    """
    lt = jnp.zeros((keys.shape[0], keys.shape[0]), jnp.bool_)
    eq = jnp.ones((keys.shape[0], keys.shape[0]), jnp.bool_)
    for w in range(keys.shape[1]):
        a = keys[:, w][:, None]
        c = keys[:, w][None, :]
        lt = lt | (eq & (c < a))
        eq = eq & (c == a)
    return jnp.sum(lt.astype(jnp.int32), axis=1)


def pattern_cmp_ref(sfx, pat, start, stop):
    """(B, K) suffix/pattern windows + (B,) [start, stop) token ranges ->
    (B, 2) ``[cmp, matched]`` (the batched-search compare oracle)."""
    sfx = jnp.asarray(sfx, jnp.int32)
    pat = jnp.asarray(pat, jnp.int32)
    start = jnp.asarray(start, jnp.int32)
    stop = jnp.asarray(stop, jnp.int32)
    b, k = sfx.shape
    iota = jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32)[None, :], (b, k))
    in_rng = (iota >= start[:, None]) & (iota < stop[:, None])
    eq = jnp.where(in_rng, sfx == pat, True)
    first = jnp.min(jnp.where(eq, stop[:, None], iota), axis=1)
    matched = first - start
    hit = iota == first[:, None]
    sv = jnp.sum(jnp.where(hit, sfx, 0), axis=1)
    pv = jnp.sum(jnp.where(hit, pat, 0), axis=1)
    neq = first < stop
    cmp = jnp.where(neq & (sv < pv), -1, jnp.where(neq & (sv > pv), 1, 0))
    return jnp.stack([cmp.astype(jnp.int32), matched.astype(jnp.int32)],
                     axis=1)


def bitonic_sort_tiles_ref(key_hi, key_lo, val, tile: int):
    import jax

    n = key_hi.shape[0]
    ntiles = max(1, -(-n // tile))
    pad = ntiles * tile - n
    big = jnp.iinfo(jnp.int32).max
    kh = jnp.pad(key_hi, (0, pad), constant_values=big).reshape(ntiles, tile)
    kl = jnp.pad(key_lo, (0, pad), constant_values=big).reshape(ntiles, tile)
    v = jnp.pad(val, (0, pad), constant_values=big).reshape(ntiles, tile)
    skh, skl, sv = jax.lax.sort((kh, kl, v), dimension=1, num_keys=2)
    return tuple(x.reshape(-1)[:n] for x in (skh, skl, sv))
