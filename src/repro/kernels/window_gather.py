"""Pallas kernel: server side of ``mgetsuffix`` (paper §IV-B, refs [18,19]).

Given the resident corpus shard (rows of tokens) and an aggregated batch of
(row, offset) requests, gather the K-token suffix windows.  This is what the
paper's custom Redis command does on the store side; on TPU the batched
random access becomes a **scalar-prefetch** kernel: the request arrays are
prefetched into SMEM, the BlockSpec index_map picks the corpus row per grid
step (one DMA per request), and the in-row offset slice happens in VMEM.

Grid: one step per request.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


from repro.kernels.compat import out_struct, vma_of as _vma


def _kernel(rows_ref, offs_ref, corpus_ref, out_ref, *, k):
    g = pl.program_id(0)
    off = offs_ref[g]
    row = corpus_ref[0, :]  # the row selected by index_map, (L + k,)
    out_ref[0, :] = jax.lax.dynamic_slice(row, (off,), (k,))


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def window_gather(corpus: jnp.ndarray, rows: jnp.ndarray, offs: jnp.ndarray,
                  k: int, interpret: bool = True) -> jnp.ndarray:
    """corpus (R, L) int32, rows/offs (M,) -> windows (M, k) int32.

    Out-of-range rows (< 0 or >= R) return zeros; offsets are clamped to
    [0, L] and windows past the row end are zero-padded — matching
    ``repro.core.encoding.window_at`` exactly.
    """
    r, l = corpus.shape
    m = rows.shape[0]
    # guard row R = zeros; pad columns so off+k never overruns
    padded = jnp.pad(corpus, ((0, 1), (0, k)))
    rows_c = jnp.where((rows >= 0) & (rows < r), rows, r).astype(jnp.int32)
    offs_c = jnp.clip(offs, 0, l).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1, l + k), lambda g, rows_p, offs_p: (rows_p[g], 0)),
        ],
        out_specs=pl.BlockSpec((1, k), lambda g, rows_p, offs_p: (g, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid_spec=grid_spec,
        out_shape=out_struct((m, k), jnp.int32, vma=_vma(corpus, rows, offs)),
        interpret=interpret,
    )(rows_c, offs_c, padded)
    return out
