"""Pallas kernel: Map-phase numeric prefix encoding (paper §IV-B).

Packs, for every suffix position, the next K tokens into ``n_words`` int31
key words (base-(V+1) multiply packing or bit-shift packing — both
order-preserving).  This is the hot loop of the paper's Map stage.

TPU-native formulation: instead of a gather of (B, K) windows, the kernel
reads two adjacent VMEM blocks (current + next, since K <= block) and builds
the keys from **K statically-shifted slices** with multiply-accumulate — pure
VPU element-wise work, no dynamic addressing, MXU not needed.

Grid: one step per block of B suffix positions.
BlockSpecs: tokens block i and block i+1 (the halo) in VMEM; out (B, n_words).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.config import SAConfig


from repro.kernels.compat import out_struct, vma_of as _vma


def _kernel(cur_ref, nxt_ref, out_ref, *, k, cpw, n_words, base, bits, packing):
    b = cur_ref.shape[0]
    full = jnp.concatenate([cur_ref[...], nxt_ref[...]])  # (2B,)
    for w in range(n_words):
        acc = jnp.zeros((b,), jnp.int32)
        for j in range(w * cpw, (w + 1) * cpw):
            tok = jax.lax.dynamic_slice(full, (j,), (b,))  # static j: shift
            if packing == "base":
                acc = acc * base + tok
            else:
                acc = (acc << bits) | tok
        if packing == "bits":
            acc = acc << (31 - bits * cpw)
        out_ref[:, w] = acc


@functools.partial(jax.jit, static_argnames=("cfg", "block", "interpret"))
def prefix_pack(tokens: jnp.ndarray, cfg: SAConfig, block: int = 512,
                interpret: bool = True) -> jnp.ndarray:
    """tokens (N,) int32 -> keys (N, key_words) int32.

    Window for position i is tokens[i:i+K] zero-padded past the end; callers
    wanting halo semantics append the halo to ``tokens`` and slice the result.
    """
    n = tokens.shape[0]
    k = cfg.prefix_len
    cpw = cfg.resolved_chars_per_word()
    bits = max(1, int(cfg.vocab_size).bit_length())
    assert block >= k, (block, k)
    nblocks = -(-n // block)
    # pad so block i+1 always exists and windows past N read zeros
    padded = jnp.pad(tokens, (0, (nblocks + 1) * block - n))
    kern = functools.partial(
        _kernel, k=k, cpw=cpw, n_words=cfg.key_words,
        base=cfg.vocab_size + 1, bits=bits, packing=cfg.packing,
    )
    out = pl.pallas_call(
        kern,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i + 1,)),
        ],
        out_specs=pl.BlockSpec((block, cfg.key_words), lambda i: (i, 0)),
        out_shape=out_struct(
            (nblocks * block, cfg.key_words), jnp.int32, vma=_vma(tokens)
        ),
        interpret=interpret,
    )(padded, padded)
    return out[:n]
