"""Jit'd public wrappers for the Pallas kernels.

On TPU the kernels run compiled (interpret=False); everywhere else they run
in interpret mode, which executes the same kernel bodies in Python/XLA for
bit-exact validation against ``ref.py``.
"""
from __future__ import annotations

import jax

from repro.kernels.bitonic_sort import bitonic_sort_tiles as _bitonic
from repro.kernels.bucket_hist import bucket_hist as _bucket_hist
from repro.kernels.merge_path import merge_path_ranks as _merge_path_ranks
from repro.kernels.pattern_cmp import pattern_cmp as _pattern_cmp
from repro.kernels.prefix_pack import prefix_pack as _prefix_pack
from repro.kernels.window_gather import window_gather as _window_gather


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def prefix_pack(tokens, cfg, block: int = 512):
    return _prefix_pack(tokens, cfg, block=block, interpret=_interpret())


def window_gather(corpus, rows, offs, k: int):
    return _window_gather(corpus, rows, offs, k, interpret=_interpret())


def bucket_hist(key_hi, key_lo, split_hi, split_lo, block: int = 1024):
    return _bucket_hist(
        key_hi, key_lo, split_hi, split_lo, block=block, interpret=_interpret()
    )


def bitonic_sort_tiles(key_hi, key_lo, val, tile: int = 1024):
    return _bitonic(key_hi, key_lo, val, tile=tile, interpret=_interpret())


def merge_path_ranks(keys, block: int = 256):
    return _merge_path_ranks(keys, block=block, interpret=_interpret())


def pattern_cmp(sfx, pat, start, stop, block: int = 256):
    return _pattern_cmp(sfx, pat, start, stop, block=block,
                        interpret=_interpret())
