"""Pallas kernel: TeraSort range partitioner (paper §IV-A).

bucket(key) = #splitters lexicographically-less-than key — equal keys always
land in the same bucket (the MapReduce same-key-same-reducer invariant that
keeps one sorting group on one reducer).  Also emits per-block histograms so
the shuffle capacities can be planned.

Grid: one step per block of B keys.  Splitters stay resident in VMEM
(<= 511 x 2 int32 — a few KB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


from repro.kernels.compat import out_struct, vma_of as _vma


def _kernel(kh_ref, kl_ref, sh_ref, sl_ref, bucket_ref, hist_ref, *, d):
    kh = kh_ref[...]  # (B,)
    kl = kl_ref[...]
    sh = sh_ref[...]  # (D-1,)
    sl = sl_ref[...]
    gt = (kh[:, None] > sh[None, :]) | (
        (kh[:, None] == sh[None, :]) & (kl[:, None] > sl[None, :])
    )
    bucket = jnp.sum(gt.astype(jnp.int32), axis=1)
    bucket_ref[...] = bucket
    onehot = (bucket[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, d), 1))
    hist_ref[0, :] = jnp.sum(onehot.astype(jnp.int32), axis=0)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def bucket_hist(key_hi: jnp.ndarray, key_lo: jnp.ndarray,
                split_hi: jnp.ndarray, split_lo: jnp.ndarray,
                block: int = 1024, interpret: bool = True):
    """keys (N,), splitters (D-1,) -> (bucket (N,), hist (D,))."""
    n = key_hi.shape[0]
    d = split_hi.shape[0] + 1
    nblocks = -(-n // block)
    pad = nblocks * block - n
    # padded keys get the maximum key: counted into the last bucket, which the
    # caller subtracts (returned hist is corrected here).
    kh = jnp.pad(key_hi, (0, pad), constant_values=jnp.iinfo(jnp.int32).max)
    kl = jnp.pad(key_lo, (0, pad), constant_values=jnp.iinfo(jnp.int32).max)
    bucket, hist = pl.pallas_call(
        functools.partial(_kernel, d=d),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((d - 1,), lambda i: (0,)),
            pl.BlockSpec((d - 1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_shape=[
            out_struct((nblocks * block,), jnp.int32, vma=_vma(key_hi)),
            out_struct((nblocks, d), jnp.int32, vma=_vma(key_hi)),
        ],
        interpret=interpret,
    )(kh, kl, split_hi, split_lo)
    hist = jnp.sum(hist, axis=0)
    hist = hist.at[d - 1].add(-pad)  # remove padding keys
    return bucket[:n], hist
