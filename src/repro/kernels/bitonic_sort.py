"""Pallas kernel: VMEM-resident bitonic sorter for (key_hi, key_lo, value).

The reducer-side "sorting group" sorter: the paper accumulates sorting groups
up to a threshold (1.6e6 suffixes) so each sort fits comfortably in memory
(§IV-C).  The TPU analogue is a tile that fits VMEM, sorted in-place with a
bitonic network — log^2(T) compare-exchange stages of pure element-wise
min/max/select, no dynamic addressing (each stage uses static reshapes to
pair partners at distance j), so the whole tile stays VMEM-resident.

Lexicographic order on (key_hi, key_lo); ``value`` rides along (carries the
packed suffix index).  Ascending, not stable (callers append a unique value
column to the keys when determinism matters — the pipeline always does).

Grid: one step per tile; tiles are sorted independently (the caller merges
or, as in the tie-break loop, tiles are pre-partitioned sorting groups).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


from repro.kernels.compat import out_struct, vma_of as _vma


def _cmp_exchange(kh, kl, v, j, asc):
    """One compare-exchange stage at partner distance j.

    asc: (T,) bool — ascending flag per element (same for both partners).
    """
    t = kh.shape[0]

    def pair(x):
        return x.reshape(t // (2 * j), 2, j)

    def unpair(x):
        return x.reshape(t)

    ph, pl_, pv, pa = pair(kh), pair(kl), pair(v), pair(asc)
    ah, al, av = ph[:, 0], pl_[:, 0], pv[:, 0]
    bh, bl, bv = ph[:, 1], pl_[:, 1], pv[:, 1]
    a_gt_b = (ah > bh) | ((ah == bh) & (al > bl))
    swap = jnp.where(pa[:, 0], a_gt_b, ~a_gt_b)
    nah = jnp.where(swap, bh, ah)
    nbh = jnp.where(swap, ah, bh)
    nal = jnp.where(swap, bl, al)
    nbl = jnp.where(swap, al, bl)
    nav = jnp.where(swap, bv, av)
    nbv = jnp.where(swap, av, bv)
    kh = unpair(jnp.stack([nah, nbh], axis=1))
    kl = unpair(jnp.stack([nal, nbl], axis=1))
    v = unpair(jnp.stack([nav, nbv], axis=1))
    return kh, kl, v


def _kernel(kh_ref, kl_ref, v_ref, okh_ref, okl_ref, ov_ref, *, t):
    kh, kl, v = kh_ref[...], kl_ref[...], v_ref[...]
    idx = jax.lax.iota(jnp.int32, t)
    k = 2
    while k <= t:
        asc = (idx & k) == 0
        j = k // 2
        while j >= 1:
            kh, kl, v = _cmp_exchange(kh, kl, v, j, asc)
            j //= 2
        k *= 2
    okh_ref[...], okl_ref[...], ov_ref[...] = kh, kl, v


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def bitonic_sort_tiles(key_hi, key_lo, val, tile: int = 1024,
                       interpret: bool = True):
    """Sort each ``tile``-sized chunk of (key_hi, key_lo, val) independently.

    Inputs are padded to a multiple of ``tile`` with max-int keys (which sort
    to the end of their tile).  tile must be a power of two.
    """
    assert tile & (tile - 1) == 0, "tile must be a power of two"
    n = key_hi.shape[0]
    ntiles = max(1, -(-n // tile))
    pad = ntiles * tile - n
    big = jnp.iinfo(jnp.int32).max
    kh = jnp.pad(key_hi, (0, pad), constant_values=big)
    kl = jnp.pad(key_lo, (0, pad), constant_values=big)
    v = jnp.pad(val, (0, pad), constant_values=big)
    outs = pl.pallas_call(
        functools.partial(_kernel, t=tile),
        grid=(ntiles,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))] * 3,
        out_specs=[pl.BlockSpec((tile,), lambda i: (i,))] * 3,
        out_shape=[out_struct(
            (ntiles * tile,), jnp.int32, vma=_vma(key_hi)
        )] * 3,
        interpret=interpret,
    )(kh, kl, v)
    return tuple(o[:n] for o in outs)
